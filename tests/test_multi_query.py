"""Multi-query engine: shared-substrate write-once semantics, cross-query
plan dedup, and vmapped answer selection vs independent operators."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MultiQueryConfig,
    MultiQueryEngine,
    OperatorConfig,
    Or,
    Predicate,
    ProgressiveQueryOperator,
    build_query_set,
    compile_query,
    conjunction,
    fallback_decision_table,
)
from repro.core.combine import default_combine_params, subset_columns as combine_subset
from repro.core.plan import Plan, merge_plans_dedup
from repro.core.state import apply_outputs_to_substrate, init_substrate
from repro.data.synthetic import make_corpus
from repro.enrich.simulated import SimulatedBank, subset_columns as bank_subset

P_GLOBAL, F, N = 4, 4, 160


def _world(seed=0, selectivity=(0.3, 0.4, 0.25, 0.35)):
    preds = [Predicate(i, 1) for i in range(P_GLOBAL)]
    corpus = make_corpus(
        jax.random.PRNGKey(seed), N, [p.tag_type for p in preds],
        [p.tag for p in preds], selectivity=list(selectivity),
    )
    bank = SimulatedBank(outputs=corpus.func_probs, costs=corpus.costs)
    combine = default_combine_params(corpus.aucs)
    table = fallback_decision_table(P_GLOBAL, F, corpus.aucs)
    return preds, corpus, bank, combine, table


def _engine(queries, preds, bank, combine, table, **cfg_kw):
    qset = build_query_set(queries, global_predicates=[p.positive() for p in preds])
    cfg = MultiQueryConfig(**{"plan_size": 32, **cfg_kw})
    return MultiQueryEngine(qset, table, combine, bank.costs, bank, cfg)


# ----------------------------------------------- shared substrate semantics --


def test_substrate_write_once_marks_all_queries():
    """Executing a triple for query A marks it executed for query B."""
    preds, corpus, bank, combine, table = _world()
    qa = conjunction(preds[0], preds[1])
    qb = conjunction(preds[1], preds[2])
    eng = _engine([qa, qb], preds, bank, combine, table)
    state = eng.init_state(N)

    # execute (object 7, predicate 1, function 2) — predicate 1 is shared
    sub = apply_outputs_to_substrate(
        state.substrate,
        jnp.asarray([7]), jnp.asarray([1]), jnp.asarray([2]),
        jnp.asarray([0.9]), jnp.asarray([0.5]), jnp.asarray([True]),
    )
    assert bool(sub.exec_mask[7, 1, 2])
    # the decision-table key both queries plan from reflects the write
    assert int(sub.state_id()[7, 1]) == 4  # bit 2 set

    # planning for BOTH queries must see the function as unavailable: the
    # chosen next function for (7, pred 1) can never be the executed one
    state = dataclasses.replace(state, substrate=sub)
    pp, unc, joint = eng._derive(sub)
    per = dataclasses.replace(
        state.per_query, pred_prob=pp, uncertainty=unc, joint_prob=joint
    )
    state = dataclasses.replace(state, per_query=per)
    benefits = eng._benefits_batched(state)
    assert int(benefits.next_fn[0, 7, 1]) != 2
    assert int(benefits.next_fn[1, 7, 1]) != 2


def test_substrate_charges_each_triple_once():
    """Re-executing an already-executed triple adds no cost."""
    sub = init_substrate(8, 2, 3)
    args = (
        jnp.asarray([3]), jnp.asarray([1]), jnp.asarray([0]),
        jnp.asarray([0.8]), jnp.asarray([2.5]), jnp.asarray([True]),
    )
    sub1 = apply_outputs_to_substrate(sub, *args)
    assert float(sub1.cost_spent) == pytest.approx(2.5)
    sub2 = apply_outputs_to_substrate(sub1, *args)
    assert float(sub2.cost_spent) == pytest.approx(2.5)
    # invalid lanes never charge or write
    sub3 = apply_outputs_to_substrate(
        sub1,
        jnp.asarray([4]), jnp.asarray([0]), jnp.asarray([1]),
        jnp.asarray([0.7]), jnp.asarray([9.0]), jnp.asarray([False]),
    )
    assert float(sub3.cost_spent) == pytest.approx(2.5)
    assert not bool(sub3.exec_mask[4, 0, 1])


# ------------------------------------------------------- cross-query dedup --


def test_merge_plans_dedup_no_duplicates_keeps_max_benefit():
    def plan(obj, prd, fn, ben, valid):
        k = len(obj)
        return Plan(
            object_idx=jnp.asarray(obj, jnp.int32),
            pred_idx=jnp.asarray(prd, jnp.int32),
            func_idx=jnp.asarray(fn, jnp.int32),
            benefit=jnp.asarray(ben, jnp.float32),
            cost=jnp.full((k,), 1.0, jnp.float32),
            valid=jnp.asarray(valid, bool),
        )

    p0 = plan([5, 3, 9], [0, 1, 0], [2, 2, 1], [5.0, 4.0, 3.0], [1, 1, 1])
    p1 = plan([5, 3, 7], [0, 1, 1], [2, 2, 0], [7.0, 1.0, 2.0], [1, 1, 0])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), p0, p1)
    merged = merge_plans_dedup(stacked, num_predicates=2, num_functions=3)

    keys = [
        (int(o), int(p), int(f))
        for o, p, f, v in zip(
            merged.object_idx, merged.pred_idx, merged.func_idx, merged.valid
        )
        if bool(v)
    ]
    assert len(keys) == len(set(keys)), "merged plan contains duplicate triples"
    assert set(keys) == {(5, 0, 2), (3, 1, 2), (9, 0, 1)}
    # duplicate (5,0,2) kept the max benefit across queries
    i = keys.index((5, 0, 2))
    assert float(merged.benefit[i]) == pytest.approx(7.0)
    # budget masks the cheapest-benefit tail
    budgeted = merge_plans_dedup(
        stacked, num_predicates=2, num_functions=3, cost_budget=2.0
    )
    assert int(budgeted.num_valid()) == 2


def test_duplicate_queries_cost_like_one():
    """Q identical queries cost ~1x a single query, not Qx."""
    preds, corpus, bank, combine, table = _world()
    q = conjunction(preds[0], preds[1])

    eng1 = _engine([q], preds, bank, combine, table)
    s1, h1 = eng1.run(N, 5)

    eng4 = _engine([q] * 4, preds, bank, combine, table)
    s4, h4 = eng4.run(N, 5)

    assert float(s4.cost_spent) == pytest.approx(float(s1.cost_spent), rel=1e-5)
    # every epoch's merged plan matched the single-query volume
    for a, b in zip(h1, h4):
        assert b.merged_valid == a.merged_valid
        # and the dedup accounting shows ~4x requested vs executed
        assert b.requested_cost == pytest.approx(4 * a.requested_cost, rel=1e-4)
    # all four tenants got identical answers
    for i in range(1, 4):
        np.testing.assert_array_equal(
            np.asarray(s4.per_query.in_answer[i]),
            np.asarray(s4.per_query.in_answer[0]),
        )


# ----------------------------------- equivalence to independent operators --


@pytest.mark.parametrize("strategy", ["all", "auto"])
def test_matches_independent_operators_on_disjoint_predicates(strategy):
    """Vmapped plan/selection == Q stand-alone operators when nothing overlaps."""
    preds, corpus, bank, combine, table = _world()
    cols_per_query = [[0, 1], [2, 3]]
    queries = [conjunction(*[preds[c] for c in cols]) for cols in cols_per_query]
    epochs = 5

    eng = _engine(
        queries, preds, bank, combine, table,
        candidate_strategy=strategy, function_selection="table",
    )
    mstate = eng.init_state(N)
    m_ef = []
    for _ in range(epochs):
        mstate, sel, plans, merged, _, _ = eng.run_epoch(mstate)
        m_ef.append([float(x) for x in sel.expected_f])

    indep_cost = 0.0
    for qi, cols in enumerate(cols_per_query):
        local_q = conjunction(*[Predicate(i, 1) for i in range(len(cols))])
        b = bank_subset(bank, cols)
        op = ProgressiveQueryOperator(
            local_q, table.subset(cols), combine_subset(combine, cols),
            b.costs, b,
            OperatorConfig(
                plan_size=32, candidate_strategy=strategy,
                function_selection="table",
            ),
        )
        st = op.init_state(N)
        for e in range(epochs):
            st, sel, plan, _ = op.run_epoch(st)
            assert float(sel.expected_f) == pytest.approx(m_ef[e][qi], abs=1e-5)
        np.testing.assert_array_equal(
            np.asarray(mstate.per_query.in_answer[qi]), np.asarray(st.in_answer)
        )
        indep_cost += float(st.cost_spent)
    assert float(mstate.cost_spent) == pytest.approx(indep_cost, rel=1e-5)


# ------------------------------------------------- admission + general ASTs --


def test_admission_warm_starts_from_substrate():
    preds, corpus, bank, combine, table = _world()
    eng = _engine([conjunction(preds[0], preds[1])], preds, bank, combine, table)
    state = eng.init_state(N)
    for _ in range(3):
        state, *_ = eng.run_epoch(state)
    spent = float(state.cost_spent)

    state = eng.admit(state, conjunction(preds[1], preds[2]))
    assert eng.query_set.num_queries == 2
    assert state.per_query.num_queries == 2
    assert float(state.cost_spent) == pytest.approx(spent)  # admission is free
    # the admitted query's derived state reflects prior enrichment of its
    # shared predicate column: joint != cold prior wherever pred 1 was enriched
    enriched = np.asarray(state.substrate.exec_mask[:, 1, :].any(axis=-1))
    assert enriched.any()
    joint_new = np.asarray(state.per_query.joint_prob[1])
    assert not np.allclose(joint_new[enriched], 0.25)
    # and the engine keeps running with Q=2
    state, sel, plans, merged, _, _ = eng.run_epoch(state)
    assert sel.mask.shape[0] == 2

    # contract guards: truth-mask symmetry, 'best' needs conjunctive tenants
    with pytest.raises(ValueError):
        eng.admit(state, conjunction(preds[3]), truth_mask=jnp.zeros((N,), bool))
    eng_best = _engine(
        [conjunction(preds[0])], preds, bank, combine, table,
        function_selection="best",
    )
    st_b = eng_best.init_state(N)
    with pytest.raises(NotImplementedError):
        eng_best.admit(st_b, compile_query(Or(preds[0], preds[1])))


def test_admit_rejects_predicates_outside_compiled_space():
    """A query whose predicate set exceeds the compiled num_predicates fails
    loudly at admission, not deep inside evaluate_batched."""
    preds, corpus, bank, combine, table = _world()
    eng = _engine([conjunction(preds[0], preds[1])], preds, bank, combine, table)
    state = eng.init_state(N)
    alien = Predicate(17, 1)
    with pytest.raises(ValueError, match="outside the compiled global space"):
        eng.admit(state, conjunction(preds[0], alien))
    # QuerySet.add enforces the same contract for direct callers
    with pytest.raises(ValueError, match="outside the compiled global space"):
        eng.query_set.add(conjunction(alien))
    # the engine is untouched by the failed admission
    assert eng.query_set.num_queries == 1
    state, sel, *_ = eng.run_epoch(state)
    assert sel.mask.shape[0] == 1


def test_admit_duplicate_tenant_dedups_via_unique_rows():
    """Admitting a duplicate of an existing tenant must join its distinct-query
    group (derived compute stays per-DISTINCT-query) with identical answers."""
    preds, corpus, bank, combine, table = _world()
    q = conjunction(preds[0], preds[1])
    eng = _engine([q, conjunction(preds[1], preds[2])], preds, bank, combine, table)
    state = eng.init_state(N)
    for _ in range(2):
        state, *_ = eng.run_epoch(state)
    assert eng.query_set.num_unique == 2
    state = eng.admit(state, conjunction(preds[0], preds[1]))
    assert eng.query_set.num_queries == 3
    assert eng.query_set.num_unique == 2  # deduped into tenant 0's group
    assert int(eng.query_set.unique_index[2]) == int(eng.query_set.unique_index[0])
    state, sel, *_ = eng.run_epoch(state)
    np.testing.assert_array_equal(np.asarray(sel.mask[2]), np.asarray(sel.mask[0]))
    np.testing.assert_array_equal(
        np.asarray(state.per_query.in_answer[2]),
        np.asarray(state.per_query.in_answer[0]),
    )


def test_admit_after_run_scan_epochs():
    """Admission after the scan driver has completed epochs: the facade's
    session is invalidated, Q grows, and both drivers keep running on the new
    shape."""
    preds, corpus, bank, combine, table = _world()
    eng = _engine([conjunction(preds[0], preds[1])], preds, bank, combine, table)
    state, hist = eng.run_scan(N, 3)
    assert eng._session is not None and eng._session[1].max_tenants == 1
    spent = float(state.cost_spent)
    state = eng.admit(state, conjunction(preds[1], preds[2]))
    assert eng._session is None  # stale Q=1 facade session dropped
    assert float(state.cost_spent) == pytest.approx(spent)
    state, hist2 = eng.run_scan(N, 3, state=state)
    assert eng._session[1].max_tenants == 2
    assert state.per_query.num_queries == 2
    assert len(hist2) == 3
    assert hist2[-1].cost_spent > spent


def test_non_conjunctive_query_set_runs():
    preds, corpus, bank, combine, table = _world()
    q_or = compile_query(Or(preds[0], preds[2]))
    q_and = conjunction(preds[1], preds[3])
    eng = _engine([q_or, q_and], preds, bank, combine, table)
    assert not eng.query_set.all_conjunctive
    state, hist = eng.run(N, 3)
    assert len(hist) == 3
    # OR semantics: joint = p0 + p2 - p0 p2 over the global columns
    pp = state.per_query.pred_prob[0]
    expect = pp[:, 0] + pp[:, 2] - pp[:, 0] * pp[:, 2]
    np.testing.assert_allclose(
        np.asarray(state.per_query.joint_prob[0]), np.asarray(expect), rtol=1e-5
    )
