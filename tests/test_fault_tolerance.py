"""Driver-side fault-tolerance mechanisms (ISSUE 7 satellites).

Host-only logic, exercised deterministically: ``StragglerMonitor`` range
partitions must stay non-negative/disjoint/covering under adversarial speed
ratios (the old rounding scheme could hand the last shard a negative-size
range), ``Heartbeat`` must refuse unknown worker ids and support explicit
remove/revive membership, and ``ElasticPolicy`` must raise the typed
``MeshShrinkError`` when the surviving chips cannot hold the model axis.
"""

import pytest

from repro.core.errors import MeshShrinkError
from repro.runtime.fault_tolerance import (
    ElasticPolicy,
    Heartbeat,
    StragglerMonitor,
)


def _check_partition(bounds, num_objects):
    """Ranges are non-negative, disjoint, contiguous, and cover [0, N)."""
    assert bounds[0][0] == 0
    assert bounds[-1][1] == num_objects
    prev_end = 0
    for start, end in bounds:
        assert start == prev_end  # contiguous + disjoint
        assert end >= start  # non-negative size
        prev_end = end


class TestStragglerRebalance:
    def test_negative_last_shard_regression(self):
        # three equal-speed shards + one 3x-slower: weights ~[.3,.3,.3,.1]
        # over 5 objects used to round to sizes [2,2,2] leaving the last
        # shard the range (6, 5) — a negative size
        mon = StragglerMonitor(num_shards=4, ema=1.0)
        for shard, t in enumerate([1.0, 1.0, 1.0, 3.0]):
            mon.record(shard, t)
        bounds = _check_partition_result = mon.rebalance_objects(5)
        _check_partition(bounds, 5)

    @pytest.mark.parametrize(
        "times,num_objects",
        [
            ([1.0, 1.0, 1.0, 3.0], 5),
            ([1e-6, 1.0, 1.0], 7),  # one absurdly fast shard
            ([1.0, 1e-6, 1e-6, 1e-6], 3),  # more shards than objects worth
            ([5.0, 1.0, 1.0, 1.0, 1.0], 1),  # single object
            ([2.0, 3.0, 5.0, 7.0, 11.0, 13.0], 97),  # ragged primes
            ([1.0] * 8, 64),  # uniform
        ],
    )
    def test_partition_invariants_adversarial(self, times, num_objects):
        mon = StragglerMonitor(num_shards=len(times), ema=1.0)
        for shard, t in enumerate(times):
            mon.record(shard, t)
        _check_partition(mon.rebalance_objects(num_objects), num_objects)

    def test_faster_shards_get_more_objects(self):
        mon = StragglerMonitor(num_shards=2, ema=1.0)
        mon.record(0, 1.0)
        mon.record(1, 3.0)
        (s0, e0), (s1, e1) = mon.rebalance_objects(100)
        assert e0 - s0 > e1 - s1

    def test_unfilled_shards_use_mean_time(self):
        mon = StragglerMonitor(num_shards=3)
        mon.record(0, 2.0)  # shards 1, 2 never reported
        _check_partition(mon.rebalance_objects(10), 10)

    def test_stragglers_under_two_filled_shards(self):
        mon = StragglerMonitor(num_shards=4)
        assert mon.stragglers() == []  # nothing recorded
        mon.record(2, 50.0)  # one filled shard is not a comparison
        assert mon.stragglers() == []

    def test_straggler_detection(self):
        mon = StragglerMonitor(num_shards=3, ema=1.0)
        mon.record(0, 1.0)
        mon.record(1, 1.1)
        mon.record(2, 4.0)
        assert mon.stragglers(factor=1.5) == [2]
        assert mon.stragglers(factor=10.0) == []


class TestHeartbeat:
    def _hb(self, n=3, timeout=10.0):
        t = [0.0]
        hb = Heartbeat(num_workers=n, timeout_s=timeout, clock=lambda: t[0])
        return hb, t

    def test_beat_unknown_worker_raises(self):
        hb, _ = self._hb(n=2)
        with pytest.raises(KeyError, match="unknown worker 5"):
            hb.beat(5)

    def test_failure_detection_and_remove(self):
        hb, t = self._hb(n=3, timeout=10.0)
        t[0] = 5.0
        hb.beat(0)
        hb.beat(2)
        t[0] = 12.0  # worker 1 last seen at 0.0 -> 12 > timeout
        assert hb.failed_workers() == [1]
        assert not hb.healthy()
        hb.remove(1)  # driver acknowledges; stops re-reporting
        assert hb.failed_workers() == []
        with pytest.raises(KeyError):  # a removed worker may not beat
            hb.beat(1)
        with pytest.raises(KeyError):  # remove is not idempotent by design
            hb.remove(1)

    def test_revive_rejoins_as_healthy(self):
        hb, t = self._hb(n=2, timeout=5.0)
        t[0] = 20.0
        assert sorted(hb.failed_workers()) == [0, 1]
        hb.remove(0)
        hb.revive(0)  # explicit rejoin: healthy as of now
        assert hb.failed_workers() == [1]
        hb.beat(0)  # and it may beat again

    def test_revive_out_of_range_raises(self):
        hb, _ = self._hb(n=2)
        with pytest.raises(KeyError, match=r"\[0, 2\)"):
            hb.revive(2)
        with pytest.raises(KeyError):
            hb.revive(-1)

    def test_revive_resets_a_timed_out_worker(self):
        hb, t = self._hb(n=1, timeout=3.0)
        t[0] = 10.0
        assert hb.failed_workers() == [0]
        hb.revive(0)  # never removed — revive still re-anchors liveness
        assert hb.failed_workers() == []


class TestElasticPolicy:
    def test_shrink_halves_data_axis(self):
        pol = ElasticPolicy(data_axis=8, model_axis=2)
        assert pol.shrink_for_failures(healthy_chips=12) == (4, 2)
        assert pol.shrink_for_failures(healthy_chips=16) == (8, 2)
        assert pol.shrink_for_failures(healthy_chips=2) == (1, 2)

    def test_two_to_one_shard_shrink(self):
        # the supervisor's CI scenario: 2 plan shards, 1 worker dies
        assert ElasticPolicy(2, 1).shrink_for_failures(1) == (1, 1)

    def test_mesh_shrink_error_is_typed(self):
        pol = ElasticPolicy(data_axis=4, model_axis=4)
        with pytest.raises(MeshShrinkError) as ei:
            pol.shrink_for_failures(healthy_chips=3)
        assert ei.value.healthy_chips == 3
        assert ei.value.model_axis == 4
        assert isinstance(ei.value, RuntimeError)  # the pre-typed contract
