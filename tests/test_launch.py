"""Launch-layer tests: mesh construction, sharding rules, small-mesh AOT
lowering of every step kind (the 512-device run lives in launch/dryrun.py),
end-to-end smoke training, and the progressive serve driver."""

import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, all_cells, shape_applicable
from repro.launch.mesh import make_host_mesh
from repro.launch.rules import rules_for_cell


def test_all_cells_inventory():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c["runnable"]]
    skipped = [c for c in cells if not c["runnable"]]
    assert len(runnable) == 34
    assert len(skipped) == 6
    assert all(c["shape"] == "long_500k" for c in skipped)


def test_long500k_applicability_matches_design():
    runs = {"gemma2-9b", "h2o-danube-1.8b", "hymba-1.5b", "mamba2-370m"}
    for arch in ARCHS:
        cfg = get_config(arch)
        ok, _ = shape_applicable(cfg, "long_500k")
        assert ok == (arch in runs), arch


def test_rules_divisibility_fallbacks():
    import jax as _jax

    mesh = _jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # arctic: 56 heads not divisible by 16 -> shard head_dim instead
    r = rules_for_cell(get_config("arctic-480b"), FakeMesh(), "train", 256)
    assert r.rules["heads"] is None and r.rules["head_dim"] == "model"
    # seamless: vocab 256206 not divisible -> unsharded vocab
    r = rules_for_cell(get_config("seamless-m4t-large-v2"), FakeMesh(), "train", 256)
    assert r.rules["vocab"] is None
    # arctic experts 128 divisible by data 16 -> expert parallel
    r = rules_for_cell(get_config("arctic-480b"), FakeMesh(), "train", 256)
    assert r.rules["experts"] == "data"
    # grok experts 8 not divisible -> replicated expert dim
    r = rules_for_cell(get_config("grok-1-314b"), FakeMesh(), "train", 256)
    assert r.rules["experts"] is None
    # decode with batch 1: kv_seq spreads over everything
    r = rules_for_cell(get_config("gemma2-9b"), FakeMesh(), "decode", 1)
    assert r.rules["batch"] is None
    assert "model" in tuple(r.rules["kv_seq"])


def test_spec_never_reuses_mesh_axis():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    r = rules_for_cell(get_config("gemma2-9b"), FakeMesh(), "decode", 128)
    spec = r.spec(("layers", "batch", "kv_seq", "kv_heads", "head_dim"))
    used = []
    for entry in spec:
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        used.extend(entries)
    assert len(used) == len(set(used))


@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k", "decode_32k"])
@pytest.mark.slow
def test_small_mesh_lower_compile(shape_name):
    """Every step kind lowers+compiles on an 8-device mesh in a subprocess
    (keeps this process single-device)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, dataclasses
        from repro.configs.archs import get_config
        from repro.configs.shapes import SHAPES
        from repro.launch.steps import build_step
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        spec = dataclasses.replace(
            SHAPES["{shape_name}"],
            seq_len=128 if "{shape_name}" != "train_4k" else 64,
            global_batch=8,
        )
        for arch in ("qwen3-1.7b", "grok-1-314b", "mamba2-370m",
                     "seamless-m4t-large-v2", "hymba-1.5b"):
            cfg = get_config(arch, smoke=True)
            built = build_step(cfg, spec, mesh)
            built.fn.lower(*built.args).compile()
            print(arch, "OK")
        print("ALL_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=str(Path.cwd() / "src")),
        timeout=900,
    )
    assert "ALL_OK" in out.stdout, out.stderr[-3000:]


@pytest.mark.slow
def test_train_loop_descends_and_checkpoints(tmp_path):
    from repro.launch.train import train_loop

    cfg = get_config("qwen3-1.7b", smoke=True)
    shape = ShapeSpec("t", "train", 32, 4)
    mesh = make_host_mesh()
    with mesh:
        params, opt_state, hist = train_loop(
            cfg, shape, mesh, steps=12, ckpt_dir=str(tmp_path), ckpt_every=5,
            log_every=100,
        )
    assert hist[-1]["loss"] < hist[0]["loss"]
    from repro.checkpoint.store import latest_step

    assert latest_step(tmp_path) == 10
    # resume continues from the checkpoint
    with mesh:
        _, _, hist2 = train_loop(
            cfg, shape, mesh, steps=14, ckpt_dir=str(tmp_path), log_every=100,
        )
    assert hist2[0]["step"] == 10


@pytest.mark.slow
def test_serve_driver_end_to_end():
    from repro.launch.serve import build_server, serve_query

    op, corpus, truth, qualities = build_server(
        num_objects=192, num_preds=1, backbone_arch="qwen3-1.7b", seed=0
    )
    # cascade quality must increase with level cost (Table-1 property)
    q = qualities[0]
    assert q[-1] > 0.6
    report = serve_query(op, 192, epochs=25)
    assert report.epochs > 0
    assert report.expected_f > 0
    assert report.true_f1 is not None and report.true_f1 > 0.2


@pytest.mark.slow
def test_serve_early_termination_budget():
    from repro.launch.serve import build_server, serve_query

    op, *_ = build_server(num_objects=128, num_preds=1,
                          backbone_arch=None, seed=1)
    full = serve_query(op, 128, epochs=40)
    early = serve_query(op, 128, epochs=40,
                        target_expected_f=full.expected_f * 0.6)
    assert early.cost_spent <= full.cost_spent
    assert early.epochs <= full.epochs
