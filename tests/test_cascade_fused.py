"""Fused traceable model-cascade bank: execute parity vs the host oracle,
ragged-cascade planning exclusion, and scan-driver routing.

Tolerance contract (documented in README "Real-model enrichment"): the fused
``execute`` and the host ``execute_host`` compute the same math, but the
stacked-parameter dispatch reassociates the probe/head contractions, so
probabilities agree to f32 rounding (atol 1e-5 here; observed ~1e-7 at these
shapes).  Answer sets and cost_spent between the fused scan driver and the
legacy per-epoch loop must agree exactly / to 1-ulp float aggregation.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import get_config
from repro.core import (
    MultiQueryConfig,
    MultiQueryEngine,
    OperatorConfig,
    Predicate,
    ProgressiveQueryOperator,
    build_query_set,
    conjunction,
    learn_decision_table,
)
from repro.core.combine import fit_combine_weights
from repro.core.executor import EpochProgram, scan_capable
from repro.core.plan import Plan
from repro.core.session import EngineSession
from repro.data.synthetic import make_corpus, split_corpus, truth_answer_mask
from repro.enrich.cascade import (
    SENTINEL_COST_S,
    ModelCascadeBank,
    build_cascade,
    build_cascade_suite,
)

PROB_ATOL = 1e-5  # fused-vs-host probability tolerance (f32 reassociation)

FEATURE_DIM = 8


def _probe_bank(num_preds=3, n=48, seed=0, ragged_pred=None):
    """Probe-only cascade bank (linear + MLP levels, no backbone).

    ``ragged_pred`` truncates that predicate's cascade to 1 level, making the
    bank ragged (F=2 with an unavailable (ragged_pred, 1) slot).
    """
    suite = build_cascade_suite(
        jax.random.PRNGKey(seed), num_preds, FEATURE_DIM
    )
    if ragged_pred is not None:
        suite[ragged_pred] = suite[ragged_pred][:1]
    feats = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, FEATURE_DIM))
    return ModelCascadeBank(cascades=suite, features=feats)


def _backbone_bank(num_preds=2, n=24, seed=0):
    cfg = get_config("qwen3-1.7b", smoke=True)
    suite = build_cascade_suite(
        jax.random.PRNGKey(seed), num_preds, FEATURE_DIM, backbone_cfg=cfg
    )
    feats = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, FEATURE_DIM))
    return ModelCascadeBank(cascades=suite, features=feats)


def _random_plan(bank, m=32, seed=0, all_invalid=False):
    """A merged-plan-shaped Plan with duplicate lanes and partial validity,
    restricted to available (pred, level) pairs (the planner's guarantee)."""
    rng = np.random.default_rng(seed)
    n = bank.features.shape[0]
    p, f = bank.costs.shape
    avail = np.asarray(bank.available)
    pairs = np.argwhere(avail)
    pick = pairs[rng.integers(0, len(pairs), m)]
    valid = np.zeros(m, bool) if all_invalid else rng.random(m) < 0.75
    return Plan(
        object_idx=jnp.asarray(rng.integers(0, n, m), jnp.int32),
        pred_idx=jnp.asarray(pick[:, 0], jnp.int32),
        func_idx=jnp.asarray(pick[:, 1], jnp.int32),
        cost=jnp.zeros(m),
        benefit=jnp.zeros(m),
        valid=jnp.asarray(valid),
    )


def _operator_setup(bank, num_preds, n, seed=0, host_loop=False):
    """Operator over a planted corpus whose enrichment is the cascade bank."""
    rng = jax.random.PRNGKey(seed + 7)
    preds = [Predicate(i, 1) for i in range(num_preds)]
    corpus = make_corpus(
        rng, n + 128, [p.tag_type for p in preds], [p.tag for p in preds],
        selectivity=[0.3] * num_preds, feature_dim=FEATURE_DIM,
    )
    train, evalc = split_corpus(corpus, 128)
    # train outputs come from the bank's own levels over train features
    p, f = bank.costs.shape
    outs = np.full((train.features.shape[0], p, f), 0.5, np.float32)
    for i, casc in enumerate(bank.cascades):
        for j, lvl in enumerate(casc):
            outs[:, i, j] = np.asarray(lvl.apply_fn(lvl.params, train.features))
    combine = fit_combine_weights(
        jnp.asarray(outs), train.truth_pred[:, :p].astype(jnp.float32), steps=50
    )
    table = learn_decision_table(
        jnp.asarray(outs), combine, num_bins=8,
        costs=bank.costs, cost_normalized=True,
    )
    query = conjunction(*preds)
    served = _HostLoopBank(bank) if host_loop else bank
    op = ProgressiveQueryOperator(
        query, table, combine, bank.costs, served,
        OperatorConfig(plan_size=16, function_selection="best"),
    )
    return op


class _HostLoopBank:
    """Pre-fusion posture: hides ``supports_scan``, delegates to the host
    oracle — forces the facades' legacy per-epoch loop."""

    def __init__(self, inner):
        self.inner = inner
        self.costs = inner.costs
        self.available = inner.available

    def execute(self, plan):
        return self.inner.execute_host(plan)


# ------------------------------------------------------- execute parity ----


def test_cascade_bank_is_traceable():
    bank = _probe_bank()
    assert bank.supports_scan is True
    assert scan_capable(bank)
    assert not hasattr(bank, "outputs")  # no precomputed buffer to gather


@pytest.mark.parametrize("seed", [0, 3])
def test_execute_parity_probe_bank(seed):
    bank = _probe_bank(seed=seed)
    plan = _random_plan(bank, m=40, seed=seed)
    fused = np.asarray(bank.execute(plan))
    host = np.asarray(bank.execute_host(plan))
    np.testing.assert_allclose(fused, host, atol=PROB_ATOL, rtol=0)
    # invalid lanes return the 0.5 prior in both paths
    inv = ~np.asarray(plan.valid)
    assert np.all(fused[inv] == 0.5)


def test_execute_parity_backbone_bank():
    bank = _backbone_bank()
    plan = _random_plan(bank, m=24, seed=1)
    fused = np.asarray(bank.execute(plan))
    host = np.asarray(bank.execute_host(plan))
    np.testing.assert_allclose(fused, host, atol=PROB_ATOL, rtol=0)


def test_execute_parity_under_jit():
    bank = _probe_bank()
    plan = _random_plan(bank, m=32, seed=2)
    eager = np.asarray(bank.execute(plan))
    jitted = np.asarray(jax.jit(bank.execute)(plan))
    np.testing.assert_allclose(jitted, eager, atol=1e-6, rtol=0)


def test_execute_empty_plan_returns_priors():
    bank = _probe_bank()
    plan = _random_plan(bank, m=16, all_invalid=True)
    np.testing.assert_array_equal(np.asarray(bank.execute(plan)), 0.5)
    np.testing.assert_array_equal(np.asarray(bank.execute_host(plan)), 0.5)


def test_execute_parity_merged_multi_query_plan():
    """Parity on a REAL merged deduplicated plan from the multi-query
    planner (not a synthetic one)."""
    num_preds, n, q = 3, 48, 3
    bank = _probe_bank(num_preds=num_preds, n=n)
    preds = [Predicate(i, 1) for i in range(num_preds)]
    queries = [
        conjunction(preds[0], preds[1]),
        conjunction(preds[1], preds[2]),
        conjunction(preds[0], preds[2]),
    ][:q]
    query_set = build_query_set(
        queries, global_predicates=[p.positive() for p in preds]
    )
    rng = jax.random.PRNGKey(11)
    corpus = make_corpus(
        rng, n + 96, [p.tag_type for p in preds], [p.tag for p in preds],
        selectivity=[0.3] * num_preds, feature_dim=FEATURE_DIM,
    )
    train, _ = split_corpus(corpus, 96)
    outs = np.full((96, num_preds, 2), 0.5, np.float32)
    for i, casc in enumerate(bank.cascades):
        for j, lvl in enumerate(casc):
            outs[:, i, j] = np.asarray(lvl.apply_fn(lvl.params, train.features))
    combine = fit_combine_weights(
        jnp.asarray(outs), train.truth_pred.astype(jnp.float32), steps=50
    )
    table = learn_decision_table(jnp.asarray(outs), combine, num_bins=8)
    engine = MultiQueryEngine(
        query_set, table, combine, bank.costs, bank,
        MultiQueryConfig(plan_size=16),
    )
    state = engine.init_state(n)
    _plans, merged = engine._plan_fn(state)
    assert int(merged.num_valid()) > 0
    fused = np.asarray(bank.execute(merged))
    host = np.asarray(bank.execute_host(merged))
    np.testing.assert_allclose(fused, host, atol=PROB_ATOL, rtol=0)


# ------------------------------------------------- ragged cascade planning --


def test_ragged_cascade_cost_padding_is_sentinel_not_zero():
    bank = _probe_bank(ragged_pred=1)
    costs = np.asarray(bank.costs)
    avail = np.asarray(bank.available)
    assert not avail[1, 1]
    assert costs[1, 1] == SENTINEL_COST_S
    assert (costs[avail] < 1.0).all()  # real levels: honest FLOP seconds


@pytest.mark.parametrize("host_loop", [False, True])
def test_ragged_cascade_never_plans_missing_level(host_loop):
    """A 1-level cascade next to 2-level ones: driving the operator to
    exhaustion through EITHER driver never executes (or bills) the missing
    level of the short cascade."""
    num_preds, n = 3, 48
    bank = _probe_bank(num_preds=num_preds, n=n, ragged_pred=1)
    op = _operator_setup(bank, num_preds, n, host_loop=host_loop)
    state, hist = op.run(n, num_epochs=40)
    exec_mask = np.asarray(state.exec_mask)
    assert exec_mask[:, 0, :].all() and exec_mask[:, 2, :].all(), (
        "full cascades should exhaust in 40 epochs"
    )
    assert exec_mask[:, 1, 0].all()
    assert not exec_mask[:, 1, 1].any(), (
        "planner selected the nonexistent level of the short cascade"
    )
    assert float(state.cost_spent) < SENTINEL_COST_S / 1e6, (
        "a sentinel-cost (missing) level was billed"
    )


# ------------------------------------------------------- driver routing ----


def test_scan_driver_selected_for_cascade_bank_and_loop_branch_gone():
    num_preds, n = 2, 32
    bank = _probe_bank(num_preds=num_preds, n=n)
    op = _operator_setup(bank, num_preds, n)
    state, hist = op.run(n, num_epochs=6)
    # the facade built a session around the bank: its program traces the
    # bank's execute inside the fused superstep
    assert op._session is not None
    session = op._session[1]
    assert session.bank is bank
    assert session.program.bank is bank
    assert session.superstep_traces >= 1
    # the legacy loop's cascade branch is gone: no run_loop anywhere
    assert not hasattr(EpochProgram, "run_loop")
    assert not hasattr(EngineSession, "run_loop")


def test_epoch_program_rejects_opaque_banks():
    bank = _probe_bank()
    opaque = _HostLoopBank(bank)
    assert not scan_capable(opaque)
    op = _operator_setup(bank, 3, 48)
    with pytest.raises(ValueError, match="supports_scan"):
        EpochProgram(
            op.table, op.combine_params, bank.costs, op._engine_config(),
            bank=opaque,
        )


def test_fused_scan_matches_host_loop_end_to_end():
    """Same workload, both postures: fused in-scan cascade vs the host-
    grouping per-epoch loop — answers exactly equal, spend to 1 ulp."""
    num_preds, n, epochs = 3, 48, 12
    bank = _probe_bank(num_preds=num_preds, n=n)
    op_scan = _operator_setup(bank, num_preds, n)
    op_loop = _operator_setup(bank, num_preds, n, host_loop=True)
    st_scan, hist_scan = op_scan.run(n, num_epochs=epochs)
    st_loop, hist_loop = op_loop.run(n, num_epochs=epochs)
    assert len(hist_scan) == len(hist_loop)
    for a, b in zip(hist_scan, hist_loop):
        assert np.isclose(a.cost_spent, b.cost_spent, rtol=1e-5)
        assert a.answer_size == b.answer_size
    np.testing.assert_array_equal(
        np.asarray(st_scan.in_answer), np.asarray(st_loop.in_answer)
    )


def test_session_quarantines_ragged_bank_missing_levels():
    """EngineSession(bank=ragged) opens with the missing (pred, level)
    pairs in the quarantine channel — structurally unplannable."""
    num_preds, n = 2, 24
    bank = _probe_bank(num_preds=num_preds, n=n, ragged_pred=0)
    op = _operator_setup(bank, num_preds, n)
    session = op._session_for(n)
    q = np.asarray(session._initial_quarantine())
    np.testing.assert_array_equal(q, ~np.asarray(bank.available))


def test_backbone_stack_requires_shared_trunk():
    """Per-predicate private trunks cannot stack — build_cascade_suite's
    shared-trunk layout is enforced at bank construction."""
    cfg = get_config("qwen3-1.7b", smoke=True)
    key = jax.random.PRNGKey(0)
    cascades = [
        build_cascade(jax.random.fold_in(key, i), FEATURE_DIM, backbone_cfg=cfg)
        for i in range(2)  # two PRIVATE trunks
    ]
    feats = jax.random.normal(jax.random.PRNGKey(1), (8, FEATURE_DIM))
    with pytest.raises(ValueError, match="shared trunk"):
        ModelCascadeBank(cascades=cascades, features=feats)
