"""Theorem-1 / Lemma-1 answer-set selection."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: fixed-example property testing
    from _hypothesis_fallback import given, settings, st

from repro.core.threshold import (
    expected_f_curve,
    expected_f_of_mask,
    select_answer,
    select_answer_approx,
)


def _rand_probs(seed, n):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.0, 1.0, n).astype(np.float32))


def test_curve_unimodal_theorem1():
    # Theorem 1: E(F) over prefixes rises to a single peak then falls.
    for seed in range(5):
        p = -jnp.sort(-_rand_probs(seed, 257))
        curve = np.asarray(expected_f_curve(p))
        diffs = np.sign(np.diff(curve))
        # once it decreases it never increases again
        dec = np.where(diffs < 0)[0]
        if len(dec):
            assert np.all(diffs[dec[0]:] <= 1e-7)


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_selection_is_optimal_prefix(seed):
    p = _rand_probs(seed, 129)
    sel = select_answer(p)
    # optimality against 64 random masks of every size
    rng = np.random.default_rng(seed)
    best = float(sel.expected_f)
    for _ in range(64):
        k = rng.integers(1, 129)
        mask = np.zeros(129, bool)
        mask[rng.choice(129, size=k, replace=False)] = True
        ef = float(expected_f_of_mask(p, jnp.asarray(mask)))
        assert ef <= best + 1e-5


def test_selection_matches_bruteforce_prefix():
    p = _rand_probs(3, 200)
    sel = select_answer(p)
    sorted_desc = -np.sort(-np.asarray(p))
    cs = np.cumsum(sorted_desc)
    k = sorted_desc.sum()
    m = np.arange(1, 201)
    curve = 2 * cs / (k + m)
    m_star = int(np.argmax(curve))
    assert int(sel.size) == m_star + 1
    np.testing.assert_allclose(float(sel.expected_f), curve[m_star], rtol=1e-5)


def test_mask_consistent_with_threshold():
    p = _rand_probs(7, 333)
    sel = select_answer(p)
    mask = np.asarray(sel.mask)
    thr = float(sel.threshold)
    assert np.all(np.asarray(p)[mask] >= thr - 1e-7)
    assert int(mask.sum()) == int(sel.size)


def test_approx_close_to_exact():
    for seed in range(8):
        p = _rand_probs(seed, 4096)
        exact = select_answer(p)
        approx = select_answer_approx(p, bins=4096)
        assert abs(float(exact.expected_f) - float(approx.expected_f)) < 2e-3


def test_alpha_weighting():
    p = _rand_probs(11, 100)
    # Paper Eq. 2: F_a = (1+a) Pre Rec / (a Pre + Rec); a -> 0 recovers pure
    # precision, so the selected set shrinks to the most confident objects.
    s_pre = select_answer(p, alpha=1e-3)
    s_f1 = select_answer(p, alpha=1.0)
    assert int(s_pre.size) <= int(s_f1.size)
    assert float(s_pre.expected_precision) >= float(s_f1.expected_precision) - 1e-6


def test_equal_probabilities_select_everything():
    # Diffuse/uniform case: with all P equal, every prefix has equal precision
    # and larger recall -> optimal set is the whole corpus.
    p = jnp.full((50,), 0.3)
    sel = select_answer(p)
    assert int(sel.size) == 50
