"""End-to-end progressive operator behaviour (paper sections 3/4 + Fig. 11)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OperatorConfig,
    Predicate,
    ProgressiveQueryOperator,
    StaticOrderEvaluator,
    conjunction,
    learn_decision_table,
)
from repro.core.combine import default_combine_params, fit_combine_weights
from repro.data.synthetic import make_corpus, split_corpus, truth_answer_mask
from repro.enrich.simulated import SimulatedBank, preprocess_cheapest

AUCS = [0.60, 0.88, 0.93, 0.97]
COSTS = [0.023, 0.114, 0.42, 0.949]


@pytest.fixture(scope="module")
def setup():
    rng = jax.random.PRNGKey(0)
    query = conjunction(Predicate(0, 1), Predicate(1, 2))
    corpus = make_corpus(
        rng, 512 + 512, [0, 1], [1, 2], selectivity=[0.3, 0.4],
        aucs=AUCS, costs=COSTS,
    )
    train, evalc = split_corpus(corpus, 512)
    combine = fit_combine_weights(
        train.func_probs, train.truth_pred.astype(jnp.float32), steps=120
    )
    table = learn_decision_table(train.func_probs, combine, num_bins=10)
    truth = truth_answer_mask(evalc, query)
    bank = SimulatedBank(outputs=evalc.func_probs, costs=evalc.costs)
    pre_p, pre_m, _ = preprocess_cheapest(evalc.func_probs, evalc.costs)
    return dict(query=query, combine=combine, table=table, truth=truth,
                bank=bank, evalc=evalc, pre=(pre_p, pre_m))


def _run(setup, cfg, epochs=60):
    op = ProgressiveQueryOperator(
        setup["query"], setup["table"], setup["combine"], setup["evalc"].costs,
        setup["bank"], cfg, truth_mask=setup["truth"],
    )
    n = setup["evalc"].truth_pred.shape[0]
    st0 = op.warm_start(op.init_state(n), *setup["pre"])
    return op.run(n, num_epochs=epochs, state=st0)


def test_quality_improves_over_run(setup):
    _, hist = _run(setup, OperatorConfig(plan_size=32))
    assert len(hist) > 3
    assert hist[-1].true_f1 > hist[0].true_f1
    assert hist[-1].expected_f > 0


def test_cost_accounting_monotone(setup):
    _, hist = _run(setup, OperatorConfig(plan_size=32))
    costs = [h.cost_spent for h in hist]
    assert all(b >= a for a, b in zip(costs, costs[1:]))
    # plan costs sum to total cost
    np.testing.assert_allclose(
        costs[-1], sum(h.plan_cost for h in hist), rtol=1e-4
    )


def test_exhaustion_terminates(setup):
    state, hist = _run(setup, OperatorConfig(plan_size=512), epochs=100)
    # every (object, predicate, function) executed at most F times
    assert bool(jnp.all(state.exec_mask.sum(-1) <= 4))
    # run stops when nothing remains
    assert hist[-1].plan_valid == 0 or len(hist) == 100
    # everything enriched by then
    assert float(state.exec_mask.mean()) > 0.95


def test_budgeted_epochs_respect_budget(setup):
    cfg = OperatorConfig(plan_size=256, epoch_cost_budget=5.0)
    _, hist = _run(setup, cfg, epochs=5)
    for h in hist:
        assert h.plan_cost <= 5.0 + 1.0  # one-triple slack


def test_function_selection_best_no_worse_final(setup):
    _, h_table = _run(setup, OperatorConfig(plan_size=64), epochs=80)
    _, h_best = _run(
        setup, OperatorConfig(plan_size=64, function_selection="best"), epochs=80
    )
    assert h_best[-1].true_f1 >= h_table[-1].true_f1 - 0.05


def test_caching_raises_initial_quality(setup):
    """Paper Fig. 11: warmer caches -> higher initial F1."""
    n = setup["evalc"].truth_pred.shape[0]
    op = ProgressiveQueryOperator(
        setup["query"], setup["table"], setup["combine"], setup["evalc"].costs,
        setup["bank"], OperatorConfig(plan_size=16), truth_mask=setup["truth"],
    )
    pre_p, pre_m = setup["pre"]
    # cache = second function executed on a fraction of objects
    rng = np.random.default_rng(0)
    efs = []
    for frac in (0.0, 0.5, 1.0):
        mask = np.asarray(pre_m).copy()
        rows = rng.choice(n, size=int(frac * n), replace=False)
        mask[rows, :, 3] = True  # cache the strongest function on `frac` objects
        st = op.warm_start(op.init_state(n), pre_p, jnp.asarray(mask))
        sel_ef = float(
            __import__("repro.core.threshold", fromlist=["select_answer"])
            .select_answer(st.joint_prob).expected_f
        )
        efs.append(sel_ef)
    assert efs[2] > efs[0]  # full cache strictly better than none


def test_starvation_guard_prevents_deadlock(setup):
    # Force the paper's outside-answer restriction; the guard must keep
    # making progress even when the answer set covers most of the corpus.
    cfg = OperatorConfig(plan_size=64, candidate_strategy="outside_answer")
    state, hist = _run(setup, cfg, epochs=100)
    assert float(state.cost_spent) > 100.0


def test_baselines_run_to_completion(setup):
    for name in ("baseline1", "baseline2", "incremental", "traditional"):
        ev = StaticOrderEvaluator(
            name, setup["query"], setup["combine"], setup["evalc"].costs,
            np.asarray(setup["evalc"].aucs), setup["bank"],
            OperatorConfig(plan_size=256), truth_mask=setup["truth"],
        )
        n = setup["evalc"].truth_pred.shape[0]
        pre_p, pre_m = setup["pre"]
        st, hist = ev.run(n, num_epochs=50, cached_probs=pre_p, cached_mask=pre_m)
        assert len(hist) >= 1
        assert float(st.cost_spent) > 0
        if name == "traditional":
            # withheld until done: all but the last epoch report nothing
            assert all(h.expected_f == 0.0 for h in hist[:-1])


def test_progressive_beats_baseline2_midrun(setup):
    """Paper Figs. 2-5 (qualitative): ours >= object-major baseline mid-run."""
    cfg = OperatorConfig(plan_size=64, function_selection="best")
    _, ours = _run(setup, cfg, epochs=400)
    ev = StaticOrderEvaluator(
        "baseline2", setup["query"], setup["combine"], setup["evalc"].costs,
        np.asarray(setup["evalc"].aucs), setup["bank"], cfg,
        truth_mask=setup["truth"],
    )
    n = setup["evalc"].truth_pred.shape[0]
    pre_p, pre_m = setup["pre"]
    _, b2 = ev.run(n, num_epochs=400, cached_probs=pre_p, cached_mask=pre_m)

    def f1_at(hist, c):
        out = 0.0
        for h in hist:
            if h.cost_spent <= c:
                out = h.true_f1
        return out

    mid = float(b2[-1].cost_spent) * 0.4
    assert f1_at(ours, mid) >= f1_at(b2, mid) - 1e-6
